"""Pallas TPU flash-attention prefill kernel.

Causal/bidirectional online-softmax attention with GQA, optional sliding
window (gemma2 local layers) and logit softcap. VMEM-tiled with
(q_block, head_dim) x (kv_block, head_dim) tiles feeding the MXU; fully
masked kv-blocks are skipped via ``pl.when`` on the *grid*, so the causal
lower-triangle costs ~half the FLOPs of the dense product (the HLO-level
blockwise fallback cannot skip — this is the kernel's main win besides
fusion).

Layouts:
    q   [B, H, Sq, D]
    k,v [B, KH, Skv, D]
    out [B, H, Sq, D]
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

F32 = jnp.float32
NEG = -1e30


def _kernel(
    q_ref,                # [1, 1, qb, D]
    k_ref,                # [1, 1, kb, D]
    v_ref,                # [1, 1, kb, D]
    o_ref,                # [1, 1, qb, D]
    m_scr,                # [qb, 1] f32
    l_scr,                # [qb, 1] f32
    acc_scr,              # [qb, D] f32
    *,
    q_block: int,
    kv_block: int,
    causal: bool,
    window: int | None,
    softcap: float | None,
    q_offset: int,
):
    qi = pl.program_id(2)
    ki = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = q_offset + qi * q_block
    k_start = ki * kv_block

    # block-level skip conditions (structural zeros)
    live = jnp.bool_(True)
    if causal:
        live = live & (q_start + q_block - 1 >= k_start)
    if window is not None:
        live = live & (k_start + kv_block - 1 > q_start - window)

    @pl.when(live)
    def _compute():
        q = q_ref[0, 0].astype(F32)                            # [qb, D]
        D = q.shape[-1]
        k = k_ref[0, 0].astype(F32)                            # [kb, D]
        v = v_ref[0, 0].astype(F32)
        s = jax.lax.dot_general(                               # [qb, kb]
            q * (D ** -0.5), k, (((1,), (1,)), ((), ())),
            preferred_element_type=F32,
        )
        if softcap is not None:
            s = softcap * jnp.tanh(s / softcap)
        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, (q_block, kv_block), 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (q_block, kv_block), 1)
        mask = jnp.bool_(True)
        if causal:
            mask = mask & (qpos >= kpos)
        if window is not None:
            mask = mask & (qpos - kpos < window)
        s = jnp.where(mask, s, NEG)
        m_prev = m_scr[...]                                    # [qb, 1]
        m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)
        l_scr[...] = l_scr[...] * alpha + p.sum(axis=-1, keepdims=True)
        acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot(
            p.astype(v.dtype), v, preferred_element_type=F32
        )
        m_scr[...] = m_new

    @pl.when(ki == nk - 1)
    def _finalize():
        denom = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / denom).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=(
        "causal", "window", "softcap", "q_block", "kv_block", "q_offset",
        "interpret",
    ),
)
def flash_attention(
    q: jax.Array,   # [B, H, Sq, D]
    k: jax.Array,   # [B, KH, Skv, D]
    v: jax.Array,
    *,
    causal: bool = True,
    window: int | None = None,
    softcap: float | None = None,
    q_block: int = 512,
    kv_block: int = 512,
    q_offset: int = 0,
    interpret: bool = False,
) -> jax.Array:
    B, H, Sq, D = q.shape
    KH, Skv = k.shape[1], k.shape[2]
    G = H // KH
    qb = min(q_block, Sq)
    kb = min(kv_block, Skv)
    assert Sq % qb == 0 and Skv % kb == 0
    grid = (B, H, Sq // qb, Skv // kb)
    kern = functools.partial(
        _kernel,
        q_block=qb,
        kv_block=kb,
        causal=causal,
        window=window,
        softcap=softcap,
        q_offset=q_offset,
    )
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, qb, D), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, kb, D), lambda b, h, i, j, g=G: (b, h // g, j, 0)),
            pl.BlockSpec((1, 1, kb, D), lambda b, h, i, j, g=G: (b, h // g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, qb, D), lambda b, h, i, j: (b, h, i, 0)),
        scratch_shapes=[
            pltpu.VMEM((qb, 1), F32),
            pltpu.VMEM((qb, 1), F32),
            pltpu.VMEM((qb, D), F32),
        ],
        out_shape=jax.ShapeDtypeStruct((B, H, Sq, D), q.dtype),
        interpret=interpret,
    )(q, k, v)
