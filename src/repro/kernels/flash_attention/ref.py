"""Pure-jnp oracle for flash attention (dense masked softmax)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

F32 = jnp.float32


def flash_attention_ref(
    q, k, v, *, causal=True, window=None, softcap=None, q_offset=0
):
    """q [B,H,Sq,D]; k,v [B,KH,Skv,D] -> [B,H,Sq,D]."""
    B, H, Sq, D = q.shape
    KH, Skv = k.shape[1], k.shape[2]
    G = H // KH
    qf = q.reshape(B, KH, G, Sq, D).astype(F32) * (D ** -0.5)
    s = jnp.einsum("bkgqd,bksd->bkgqs", qf, k.astype(F32))
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    qpos = q_offset + jnp.arange(Sq)[:, None]
    kpos = jnp.arange(Skv)[None, :]
    mask = jnp.ones((Sq, Skv), bool)
    if causal:
        mask &= qpos >= kpos
    if window is not None:
        mask &= (qpos - kpos) < window
    s = jnp.where(mask[None, None, None], s, -1e30)
    w = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgqs,bksd->bkgqd", w, v.astype(F32))
    return out.reshape(B, H, Sq, D).astype(q.dtype)
