"""Public entry point: Pallas flash attention on TPU, oracle elsewhere.

``REPRO_KERNEL_INTERPRET=1`` routes the off-TPU path through the Pallas
kernel in interpret mode (CI kernel-parity job); read at call time.
"""
from __future__ import annotations

import os

import jax

from repro.kernels.flash_attention.kernel import flash_attention as _pallas
from repro.kernels.flash_attention.ref import flash_attention_ref as _ref


def flash_attention(q, k, v, *, causal=True, window=None, softcap=None, q_offset=0):
    if jax.default_backend() == "tpu":
        return _pallas(
            q, k, v, causal=causal, window=window, softcap=softcap,
            q_offset=q_offset,
        )
    if os.environ.get("REPRO_KERNEL_INTERPRET", "0") == "1":
        return _pallas(
            q, k, v, causal=causal, window=window, softcap=softcap,
            q_offset=q_offset, interpret=True,
        )
    return _ref(
        q, k, v, causal=causal, window=window, softcap=softcap, q_offset=q_offset
    )


def audit_spec():
    """Example-shape jit target for :mod:`repro.analysis.jitaudit` — the
    causal prefill bucket the engine's chunked path dispatches, plus a
    double-length probe shape (same branch class, so the traced
    primitive structure must match)."""
    import functools

    import jax.numpy as jnp

    def make(seq: int):
        def args():
            q = jnp.zeros((1, seq, 4, 64), jnp.bfloat16)
            return q, q, q

        return args

    return {
        "name": "kernels.flash_attention",
        "fn": jax.jit(functools.partial(flash_attention, causal=True)),
        "make_args": make(64),
        "probe_args": make(128),
        "bucket": {"seq": 64, "heads": 4, "head_dim": 64},
    }
