"""Public entry point: Pallas flash attention on TPU, oracle elsewhere."""
from __future__ import annotations

import jax

from repro.kernels.flash_attention.kernel import flash_attention as _pallas
from repro.kernels.flash_attention.ref import flash_attention_ref as _ref


def flash_attention(q, k, v, *, causal=True, window=None, softcap=None, q_offset=0):
    if jax.default_backend() == "tpu":
        return _pallas(
            q, k, v, causal=causal, window=window, softcap=softcap,
            q_offset=q_offset,
        )
    return _ref(
        q, k, v, causal=causal, window=window, softcap=softcap, q_offset=q_offset
    )
