"""Public entry point: Pallas flash attention on TPU, oracle elsewhere.

``REPRO_KERNEL_INTERPRET=1`` routes the off-TPU path through the Pallas
kernel in interpret mode (CI kernel-parity job); read at call time.
"""
from __future__ import annotations

import os

import jax

from repro.kernels.flash_attention.kernel import flash_attention as _pallas
from repro.kernels.flash_attention.ref import flash_attention_ref as _ref


def flash_attention(q, k, v, *, causal=True, window=None, softcap=None, q_offset=0):
    if jax.default_backend() == "tpu":
        return _pallas(
            q, k, v, causal=causal, window=window, softcap=softcap,
            q_offset=q_offset,
        )
    if os.environ.get("REPRO_KERNEL_INTERPRET", "0") == "1":
        return _pallas(
            q, k, v, causal=causal, window=window, softcap=softcap,
            q_offset=q_offset, interpret=True,
        )
    return _ref(
        q, k, v, causal=causal, window=window, softcap=softcap, q_offset=q_offset
    )
