"""Agentic workload traces: generator, analysis (paper §3), persistence."""
from repro.traces.analysis import (
    PhaseStats,
    busy_phase_durations,
    percentile,
    phase_stats,
    tool_call_cdf,
)
from repro.traces.generator import (
    TraceGenConfig,
    burst_cancel_corpus,
    generate_corpus,
    generate_program,
)
from repro.traces.io import load_corpus, save_corpus

__all__ = [
    "PhaseStats",
    "TraceGenConfig",
    "burst_cancel_corpus",
    "busy_phase_durations",
    "generate_corpus",
    "generate_program",
    "load_corpus",
    "percentile",
    "phase_stats",
    "save_corpus",
    "tool_call_cdf",
]
