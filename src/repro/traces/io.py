"""JSONL persistence for trace corpora (one program per line)."""
from __future__ import annotations

import json
from pathlib import Path

from repro.core.types import ProgramTrace, RequestRecord


def save_corpus(corpus: list[ProgramTrace], path: str | Path) -> None:
    path = Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    tmp = path.with_suffix(path.suffix + ".tmp")
    with tmp.open("w") as f:
        for tr in corpus:
            f.write(
                json.dumps(
                    {
                        "program_id": tr.program_id,
                        "steps": [
                            [
                                s.input_tokens,
                                s.output_tokens,
                                round(s.tool_duration_s, 4),
                                round(s.reasoning_wall_s, 4),
                                s.tool_kind,
                            ]
                            for s in tr.steps
                        ],
                    }
                )
                + "\n"
            )
    tmp.rename(path)  # atomic publish


def load_corpus(path: str | Path) -> list[ProgramTrace]:
    out: list[ProgramTrace] = []
    with Path(path).open() as f:
        for line in f:
            d = json.loads(line)
            out.append(
                ProgramTrace(
                    program_id=d["program_id"],
                    steps=[
                        RequestRecord(
                            input_tokens=s[0],
                            output_tokens=s[1],
                            tool_duration_s=s[2],
                            reasoning_wall_s=s[3],
                            tool_kind=s[4],
                        )
                        for s in d["steps"]
                    ],
                )
            )
    return out
