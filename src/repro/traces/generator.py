"""Synthetic Claude-Code-style agentic trace generator (paper §3, §6.1).

The paper replays 186 proxy-collected Claude Code traces from SWE-bench Pro.
Those traces are not public, so we generate a corpus from a two-phase
semi-Markov model calibrated to every statistic the paper reports:

* tool-call durations are heavy-tailed over 3+ orders of magnitude (Fig. 3);
* at the 2 s threshold ~87% of calls are short, yet the ~13% long calls
  carry ~58% of total wall-clock tool time (§3.3);
* busy phases (maximal runs of short calls) last tens of seconds: median
  ~4 s / ~20 s / ~41 s at the 1 s / 2 s / 5 s thresholds (Fig. 5);
* programs issue tens of inference steps over several minutes and grow
  their context monotonically (§3.1).

``tests/test_traces.py::TestCalibration`` asserts the generated corpus
reproduces these statistics, which is the §3 "trace analysis" reproduction.
"""
from __future__ import annotations

import math
import random
from dataclasses import dataclass

from repro.core.types import ProgramTrace, RequestRecord

SHORT_KINDS = ["read", "write", "edit", "shell", "grep"]
LONG_KINDS = ["pytest", "compile", "human", "subagent"]


@dataclass
class TraceGenConfig:
    """Calibrated defaults; see module docstring for the targets."""

    # --- tool-call duration model (lognormal mixture) ---
    short_median_s: float = 0.40
    short_sigma: float = 0.90
    long_median_s: float = 3.5
    long_sigma: float = 1.05
    long_max_s: float = 600.0           # human / subagent tail: minutes
    # --- phase structure ---
    busy_calls_mean: float = 18.0       # short calls per busy phase
    idle_calls_mean: float = 2.2        # long calls per idle phase
    # --- program shape ---
    min_steps: int = 12
    mean_steps: int = 42
    max_steps: int = 120
    # --- token dynamics ---
    initial_context_mean: int = 9000    # system prompt + task + repo map
    short_result_tokens: tuple[int, int] = (100, 1600)   # file reads, greps
    long_result_tokens: tuple[int, int] = (400, 4000)    # test logs, diffs
    output_tokens_mean: int = 120       # completion per step
    output_tokens_min: int = 16
    max_context: int = 120_000
    # --- reasoning wall-clock model (collection-time decode speed) ---
    decode_tok_per_s: float = 70.0
    ttft_base_s: float = 0.4


def _lognormal(rng: random.Random, median: float, sigma: float) -> float:
    return median * math.exp(rng.gauss(0.0, sigma))


def _geometric(rng: random.Random, mean: float) -> int:
    """Geometric >= 1 with the given mean."""
    p = min(0.999, 1.0 / max(1.0, mean))
    u = rng.random()
    return max(1, int(math.log(max(u, 1e-12)) / math.log(1.0 - p)) + 1)


def generate_program(
    program_id: str, rng: random.Random, cfg: TraceGenConfig | None = None
) -> ProgramTrace:
    cfg = cfg or TraceGenConfig()
    n_steps = min(
        cfg.max_steps, cfg.min_steps + _geometric(rng, cfg.mean_steps - cfg.min_steps)
    )
    context = int(rng.gauss(cfg.initial_context_mean, cfg.initial_context_mean * 0.25))
    context = max(2000, context)
    steps: list[RequestRecord] = []
    in_busy = True  # programs start by exploring (busy phase)
    calls_left = _geometric(rng, cfg.busy_calls_mean)
    for i in range(n_steps):
        output = max(
            cfg.output_tokens_min, int(rng.expovariate(1.0 / cfg.output_tokens_mean))
        )
        if in_busy:
            dur = _lognormal(rng, cfg.short_median_s, cfg.short_sigma)
            kind = rng.choice(SHORT_KINDS)
            result_lo, result_hi = cfg.short_result_tokens
        else:
            dur = min(
                cfg.long_max_s, _lognormal(rng, cfg.long_median_s, cfg.long_sigma)
            )
            kind = rng.choice(LONG_KINDS)
            result_lo, result_hi = cfg.long_result_tokens
        reasoning = cfg.ttft_base_s + output / cfg.decode_tok_per_s
        steps.append(
            RequestRecord(
                input_tokens=min(context, cfg.max_context),
                output_tokens=output,
                tool_duration_s=dur,
                reasoning_wall_s=reasoning,
                tool_kind=kind,
            )
        )
        context = min(
            cfg.max_context, context + output + rng.randint(result_lo, result_hi)
        )
        calls_left -= 1
        if calls_left <= 0:
            in_busy = not in_busy
            mean = cfg.busy_calls_mean if in_busy else cfg.idle_calls_mean
            calls_left = _geometric(rng, mean)
    # final step's tool call is the session ending; zero it out
    steps[-1].tool_duration_s = 0.0
    return ProgramTrace(program_id=program_id, steps=steps)


def generate_corpus(
    n_programs: int = 186, seed: int = 0, cfg: TraceGenConfig | None = None
) -> list[ProgramTrace]:
    """The paper's corpus: 186 complete traces (200 attempted - 14 failed)."""
    rng = random.Random(seed)
    return [generate_program(f"trace-{i:04d}", rng, cfg) for i in range(n_programs)]


def burst_cancel_corpus() -> list[ProgramTrace]:
    """Deterministic early-tool-return scenario (no randomness), shared by
    tests/test_transfer_plane.py and benchmarks/transfer_overlap.py so the
    CI overlap gate and the pinned regression exercise the same timeline:

    pbig's mid-life context burst (50 → 120 tokens) overflows a
    ~130-token GPU tier while both programs sit in tool calls, so the
    control tick demotes the idler p1 (64 tokens materialized); pbig then
    finishes and frees the tier, and p1 returns at t≈9 — before a
    slow-link offload of its KV can complete, which is exactly the window
    the scheduler's CancelTransfer path exploits."""
    return [
        ProgramTrace("pbig", [
            RequestRecord(50, 4, 1.0, reasoning_wall_s=1.0),
            RequestRecord(120, 4, 3.0, reasoning_wall_s=1.0),
            RequestRecord(126, 4, 0.0, reasoning_wall_s=1.0),
        ]),
        ProgramTrace("p1", [
            RequestRecord(60, 4, 8.0, reasoning_wall_s=1.0),
            RequestRecord(76, 4, 0.0, reasoning_wall_s=1.0),
        ]),
    ]
