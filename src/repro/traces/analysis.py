"""Trace analysis reproducing the paper's §3 characterization.

* :func:`tool_call_cdf` — Fig. 3: CDF of tool-call durations.
* :func:`busy_phase_durations` — Fig. 5: wall-clock busy-phase durations
  under a short-call threshold (busy phase = maximal run of consecutive
  steps whose tool call is shorter than the threshold; wall-clock includes
  the inference time between those calls).
* :func:`phase_stats` — the §3.3 headline numbers (short-call fraction,
  long-call share of tool time, phase medians/p90).
"""
from __future__ import annotations

from dataclasses import dataclass

from repro.core.types import ProgramTrace


def percentile(xs: list[float], q: float) -> float:
    if not xs:
        return float("nan")
    s = sorted(xs)
    idx = min(len(s) - 1, max(0, int(round(q * (len(s) - 1)))))
    return s[idx]


def tool_call_cdf(corpus: list[ProgramTrace]) -> list[float]:
    """All tool-call durations (sorted) — plot index/n vs value for the CDF."""
    durs = [
        s.tool_duration_s
        for tr in corpus
        for s in tr.steps
        if s.tool_duration_s > 0
    ]
    durs.sort()
    return durs


def busy_phase_durations(
    corpus: list[ProgramTrace], threshold_s: float
) -> list[float]:
    """Fig. 5: wall-clock duration of each busy phase under ``threshold_s``."""
    phases: list[float] = []
    for tr in corpus:
        cur = 0.0
        n_calls = 0
        for step in tr.steps:
            if step.tool_duration_s <= 0:
                continue
            if step.tool_duration_s < threshold_s:
                cur += step.reasoning_wall_s + step.tool_duration_s
                n_calls += 1
            else:
                if n_calls > 0:
                    phases.append(cur)
                cur, n_calls = 0.0, 0
        if n_calls > 0:
            phases.append(cur)
    return phases


@dataclass
class PhaseStats:
    n_programs: int
    n_calls: int
    short_fraction: float          # fraction of calls below threshold
    long_time_share: float         # share of total tool time in long calls
    busy_median_s: float
    busy_p90_s: float
    duration_p50_s: float
    duration_p99_s: float
    orders_of_magnitude: float     # log10(p99.9 / p0.1) spread


def phase_stats(corpus: list[ProgramTrace], threshold_s: float = 2.0) -> PhaseStats:
    durs = tool_call_cdf(corpus)
    short = [d for d in durs if d < threshold_s]
    long_ = [d for d in durs if d >= threshold_s]
    phases = busy_phase_durations(corpus, threshold_s)
    total = sum(durs) or 1.0
    return PhaseStats(
        n_programs=len(corpus),
        n_calls=len(durs),
        short_fraction=len(short) / max(1, len(durs)),
        long_time_share=sum(long_) / total,
        busy_median_s=percentile(phases, 0.5),
        busy_p90_s=percentile(phases, 0.9),
        duration_p50_s=percentile(durs, 0.5),
        duration_p99_s=percentile(durs, 0.99),
        orders_of_magnitude=(
            __import__("math").log10(
                max(percentile(durs, 0.999), 1e-9) / max(percentile(durs, 0.001), 1e-9)
            )
        ),
    )
