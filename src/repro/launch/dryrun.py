import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run (deliverable e).

Lowers + compiles every (architecture x input-shape) cell on the production
meshes — 16x16 single pod and 2x16x16 multi-pod — and records
memory_analysis / cost_analysis / collective traffic per cell into a JSON
artifact that §Roofline and §Perf read.

The XLA_FLAGS line above MUST stay the first statement: jax locks the
device count on first init. Do not import jax (directly or transitively)
before it.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun                 # everything
    PYTHONPATH=src python -m repro.launch.dryrun --arch gemma2-9b \
        --shape decode_32k --mesh single
    ... --skip-existing     # resume into artifacts/dryrun.json
"""
import argparse
import gzip
import json
import time
import traceback
from pathlib import Path

import jax  # noqa: F401  (deliberate early init: locks device count under XLA_FLAGS)

from repro.configs import ARCH_IDS, get_config
from repro.launch.hlo_cost import analyze as analyze_hlo
from repro.launch.hlo_cost import parse_input_output_alias
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import build_cell, cell_skip_reason
from repro.models.config import SHAPES

ARTIFACT = Path(__file__).resolve().parents[3] / "artifacts" / "dryrun.json"

MESHES = {"single": False, "multi": True}


def run_cell(
    arch: str, shape: str, mesh_name: str, *,
    hlo_dir: Path | None = None, key: str = "", vmem_budget: int = 0,
    **build_kw,
) -> dict:
    cfg = get_config(arch)
    reason = cell_skip_reason(cfg, shape)
    if reason:
        return {"status": "skipped", "reason": reason}
    t0 = time.time()
    mesh = make_production_mesh(multi_pod=MESHES[mesh_name])
    cell = build_cell(arch, shape, mesh, **build_kw)
    lowered = cell.lower()
    t_lower = time.time() - t0
    compiled = lowered.compile()
    t_compile = time.time() - t0 - t_lower
    mem = compiled.memory_analysis()
    # peak_memory_in_bytes landed after jax 0.4.x; args+out+temp is the
    # proxy upper bound there, and peak_is_proxy marks artifact rows whose
    # peak is the proxy so cross-version comparisons aren't silently mixed
    peak = getattr(mem, "peak_memory_in_bytes", None)
    peak_is_proxy = peak is None
    if peak_is_proxy:
        peak = (mem.argument_size_in_bytes + mem.output_size_in_bytes
                + mem.temp_size_in_bytes)
    cost = compiled.cost_analysis()  # NOTE: counts while bodies ONCE
    if isinstance(cost, (list, tuple)):  # jax<=0.4.x returns [dict]
        cost = cost[0] if cost else {}
    hlo_text = compiled.as_text()
    if hlo_dir is not None and key:
        hlo_dir.mkdir(parents=True, exist_ok=True)
        with gzip.open(hlo_dir / (key.replace("|", "__") + ".hlo.gz"), "wt") as f:
            f.write(hlo_text)
    hlo = analyze_hlo(hlo_text, vmem_budget=vmem_budget)  # loop-aware
    n_dev = 1
    for v in mesh.shape.values():
        n_dev *= v
    return {
        "status": "ok",
        "kind": cell.kind,
        "mesh": mesh_name,
        "devices": n_dev,
        "tokens_per_step": cell.meta.get("tokens_per_step"),
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "peak_bytes": peak,
            "peak_is_proxy": peak_is_proxy,
            "alias_bytes": mem.alias_size_in_bytes,
        },
        # (output, param) pairs XLA actually aliased — donation requests
        # the compiler dropped show up as alias_bytes lower than the
        # carry footprint; the pair count makes that auditable per cell
        "honored_aliases": len(parse_input_output_alias(hlo_text)),
        "xla_cost_once": {  # raw XLA numbers, loop bodies counted once
            "flops": cost.get("flops", 0.0),
            "bytes_accessed": cost.get("bytes accessed", 0.0),
        },
        "cost": {  # loop-aware per-device totals (TPU-target normalized)
            "flops": hlo.flops,
            "hbm_bytes": hlo.hbm_bytes,
            "hbm_bytes_raw": hlo.hbm_bytes_raw,  # CPU-backend f32-promoted
        },
        "collectives": hlo.to_dict(),
        "fallbacks": sorted(set(map(tuple, cell.rules.fallbacks))),
    }


def reanalyze(
    results: dict, out_path: Path, archs, shapes, meshes, *,
    src_tag: str = "", vmem_budget: int = 0, assume_donation: bool = False,
) -> None:
    """Recompute cost/collectives from saved HLO (no recompile). With
    accounting levers on, results land under a derived tag
    (``vmem<N>m``/``donate``) so the baseline rows stay; with none, the
    base record is updated in place (accounting-fidelity fixes)."""
    hlo_dir = out_path.parent / "hlo"
    lever = []
    if vmem_budget:
        lever.append(f"vmem{vmem_budget >> 20}m")
    if assume_donation:
        lever.append("donate")
    for arch in archs:
        for shape in shapes:
            for mesh_name in meshes:
                base = f"{arch}|{shape}|{mesh_name}"
                src = base + (f"|{src_tag}" if src_tag else "")
                rec = results.get(src)
                if not rec or rec.get("status") != "ok":
                    continue
                f = hlo_dir / (src.replace("|", "__") + ".hlo.gz")
                if not f.exists():
                    print(f"  {src}: no saved HLO, skipping")
                    continue
                with gzip.open(f, "rt") as fh:
                    hlo = analyze_hlo(
                        fh.read(), vmem_budget=vmem_budget,
                        assume_donation=assume_donation,
                    )
                dst = src + ("|" + "+".join(lever) if lever else "")
                new = dict(rec)
                new["cost"] = {
                    "flops": hlo.flops,
                    "hbm_bytes": hlo.hbm_bytes,
                    "hbm_bytes_raw": hlo.hbm_bytes_raw,
                }
                new["collectives"] = hlo.to_dict()
                results[dst] = new
                print(
                    f"  {dst}: hbm {hlo.hbm_bytes/2**30:.1f} GiB, "
                    f"wire {hlo.total_wire_bytes/2**30:.2f} GiB"
                )
    out_path.write_text(json.dumps(results, indent=1, sort_keys=True))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all", help="arch id or 'all'")
    ap.add_argument("--shape", default="all", help="shape name or 'all'")
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--out", default=str(ARTIFACT))
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--window-limited-cache", action="store_true",
                    help="§Perf lever: gemma2 local layers cache only the window")
    ap.add_argument("--sequence-parallel", action="store_true",
                    help="§Perf lever: shard train activations over 'model' on seq")
    ap.add_argument("--pad-heads", action="store_true",
                    help="§Perf lever: pad q heads to the model-axis size "
                         "(zero-weight heads; exact) so attention shards")
    ap.add_argument("--tag", default="", help="suffix for result keys (perf runs)")
    ap.add_argument("--save-hlo", action="store_true",
                    help="save compiled HLO (gz) under artifacts/hlo/ for "
                         "re-analysis without recompiling")
    ap.add_argument("--vmem-budget", type=int, default=0,
                    help="§Perf lever: while-body temporaries <= this many "
                         "bytes stay in VMEM (Pallas-kernel accounting)")
    ap.add_argument("--assume-donation", action="store_true",
                    help="§Perf lever: entry copies/zero-inits of donated "
                         "carries alias away on the TPU target")
    ap.add_argument("--reanalyze", action="store_true",
                    help="recompute cost/collectives from saved HLO "
                         "(artifacts/hlo/) without recompiling")
    args = ap.parse_args()

    archs = ARCH_IDS if args.arch == "all" else [args.arch]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]

    out_path = Path(args.out)
    out_path.parent.mkdir(parents=True, exist_ok=True)
    results: dict = {}
    if out_path.exists():
        results = json.loads(out_path.read_text())

    build_kw = {}
    if args.window_limited_cache:
        build_kw["window_limited_cache"] = True
    if args.sequence_parallel:
        build_kw["sequence_parallel"] = True
    if args.pad_heads:
        build_kw["pad_heads"] = True

    if args.reanalyze:
        reanalyze(
            results, out_path, archs, shapes, meshes,
            src_tag=args.tag, vmem_budget=args.vmem_budget,
            assume_donation=args.assume_donation,
        )
        return

    for arch in archs:
        for shape in shapes:
            for mesh_name in meshes:
                key = f"{arch}|{shape}|{mesh_name}"
                if args.tag:
                    key += f"|{args.tag}"
                if args.skip_existing and results.get(key, {}).get("status") in (
                    "ok",
                    "skipped",
                ):
                    continue
                print(f"=== {key} ===", flush=True)
                try:
                    rec = run_cell(
                        arch, shape, mesh_name,
                        hlo_dir=(out_path.parent / "hlo") if args.save_hlo else None,
                        key=key,
                        vmem_budget=args.vmem_budget,
                        **build_kw,
                    )
                except Exception as e:  # a failure here is a bug in our system
                    rec = {
                        "status": "error",
                        "error": f"{type(e).__name__}: {e}",
                        "trace": traceback.format_exc()[-2000:],
                    }
                results[key] = rec
                out_path.write_text(json.dumps(results, indent=1, sort_keys=True))
                if rec["status"] == "ok":
                    m = rec["memory"]
                    print(
                        f"  ok ({rec['kind']}): compile {rec['compile_s']}s, "
                        f"peak/dev {m['peak_bytes']/2**30:.2f} GiB, "
                        f"args/dev {m['argument_bytes']/2**30:.2f} GiB, "
                        f"flops/dev {rec['cost']['flops']:.3e}, "
                        f"wire/dev {rec['collectives']['total_wire_bytes']/2**20:.2f} MiB",
                        flush=True,
                    )
                else:
                    print(f"  {rec['status']}: {rec.get('reason', rec.get('error'))}",
                          flush=True)

    n_ok = sum(1 for r in results.values() if r["status"] == "ok")
    n_skip = sum(1 for r in results.values() if r["status"] == "skipped")
    n_err = sum(1 for r in results.values() if r["status"] == "error")
    print(f"\ndry-run summary: {n_ok} ok, {n_skip} skipped, {n_err} errors")
    if n_err:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
