"""Cell builders: (architecture x input-shape) -> jitted, shardable step.

``train_4k`` lowers ``train_step`` (fwd + loss + grad + AdamW, donated);
``prefill_32k`` lowers ``prefill_step``; ``decode_32k``/``long_500k`` lower
``serve_step`` (one new token over a full KV cache). All inputs are
ShapeDtypeStructs — nothing here allocates device memory (dry-run contract).
"""
from __future__ import annotations

from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.dist.sharding import (
    ShardingRules,
    make_decode_rules,
    make_train_rules,
)
from repro.models import Model, ShardCtx, abstract
from repro.models.config import SHAPES, ModelConfig, ShapeConfig
from repro.models.params import Leaf, is_leaf, sharding_tree
from repro.train.optimizer import adamw_update, describe_opt_state


@dataclass
class Cell:
    arch: str
    shape: str
    kind: str
    jitted: object
    args: tuple
    rules: ShardingRules
    meta: dict = field(default_factory=dict)

    def lower(self):
        return self.jitted.lower(*self.args)


# --------------------------------------------------------------- batch specs
def batch_abstract(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    B, S = shape.global_batch, shape.seq_len
    out: dict = {}
    if shape.kind == "train":
        out["tokens"] = jax.ShapeDtypeStruct((B, S + 1), jnp.int32)
    elif shape.kind == "prefill":
        out["tokens"] = jax.ShapeDtypeStruct((B, S), jnp.int32)
    if shape.kind in ("train", "prefill"):
        if cfg.family == "encdec":
            out["frames"] = jax.ShapeDtypeStruct(
                (B, cfg.encoder_seq, cfg.d_model), jnp.bfloat16
            )
        if cfg.family == "vlm":
            out["image_embeds"] = jax.ShapeDtypeStruct(
                (B, cfg.num_image_tokens, cfg.d_model), jnp.bfloat16
            )
    return out


def batch_specs(batch: dict, mesh, rules: ShardingRules) -> dict:
    out = {}
    for k, v in batch.items():
        logical = ("batch",) + (None,) * (len(v.shape) - 1)
        out[k] = rules.sharding(mesh, logical, v.shape)
    return out


# ---------------------------------------------------------------- cell build
def build_cell(
    arch: str,
    shape_name: str,
    mesh,
    *,
    sequence_parallel: bool = False,
    window_limited_cache: bool = False,
    pad_heads: bool = False,
) -> Cell:
    from dataclasses import replace

    from repro.configs import get_config

    cfg = get_config(arch)
    if pad_heads:
        # §Perf lever: pad q heads up to the model-axis size so attention
        # shards instead of falling back to replicated (arctic: 56 -> 64).
        # Numerically exact given the checkpoint-load layout: pad heads are
        # inserted per GQA group (zero wq columns / wo rows in each group's
        # pad slots — see tests/test_attention_opts.py); the zero heads'
        # attention output projects to nothing.
        tp = mesh.shape["model"]
        padded = -(-cfg.num_heads // tp) * tp
        if padded != cfg.num_heads:
            cfg = replace(cfg, num_heads=padded)
    shape = SHAPES[shape_name]
    model = Model(cfg)
    param_tree = model.describe()

    if shape.kind == "train":
        return _build_train(arch, cfg, model, param_tree, shape, mesh,
                            sequence_parallel)
    if shape.kind == "prefill":
        return _build_prefill(arch, cfg, model, param_tree, shape, mesh)
    return _build_serve(arch, cfg, model, param_tree, shape, mesh,
                        window_limited_cache)


def _build_train(arch, cfg, model, param_tree, shape, mesh, sp):
    rules = make_train_rules(mesh, sequence_parallel=sp)
    ctx = ShardCtx(mesh, rules)
    opt_tree = describe_opt_state(param_tree, bf16_moments=cfg.bf16_moments)
    batch = batch_abstract(cfg, shape)

    p_specs = sharding_tree(param_tree, mesh, rules)
    o_specs = sharding_tree(opt_tree, mesh, rules)
    b_specs = batch_specs(batch, mesh, rules)
    scalar = NamedSharding(mesh, P())

    def train_step(params, opt_state, batch):
        def loss_fn(p):
            return model.loss(p, batch, ctx)

        (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)
        new_params, new_opt = adamw_update(grads, opt_state, params)
        return new_params, new_opt, loss

    jitted = jax.jit(
        train_step,
        in_shardings=(p_specs, o_specs, b_specs),
        out_shardings=(p_specs, o_specs, scalar),
        donate_argnums=(0, 1),
    )
    return Cell(
        arch, shape.name, "train", jitted,
        (abstract(param_tree), abstract(opt_tree), batch), rules,
        meta={"tokens_per_step": shape.global_batch * shape.seq_len},
    )


def _build_prefill(arch, cfg, model, param_tree, shape, mesh):
    rules = make_decode_rules(mesh, max(1, cfg.num_kv_heads))
    ctx = ShardCtx(mesh, rules)
    batch = batch_abstract(cfg, shape)
    p_specs = sharding_tree(param_tree, mesh, rules)
    b_specs = batch_specs(batch, mesh, rules)
    cache_tree = model.describe_cache(shape.global_batch, shape.seq_len)
    c_specs = sharding_tree(cache_tree, mesh, rules)
    logits_spec = rules.sharding(
        mesh, ("batch", "vocab_act"), (shape.global_batch, cfg.vocab_size)
    )

    def prefill_step(params, batch):
        return model.prefill(params, batch, ctx)

    jitted = jax.jit(
        prefill_step,
        in_shardings=(p_specs, b_specs),
        out_shardings=(logits_spec, c_specs),
    )
    return Cell(
        arch, shape.name, "prefill", jitted, (abstract(param_tree), batch), rules,
        meta={"tokens_per_step": shape.global_batch * shape.seq_len},
    )


def _build_serve(arch, cfg, model, param_tree, shape, mesh, window_limited):
    rules = make_decode_rules(mesh, max(1, cfg.num_kv_heads))
    ctx = ShardCtx(mesh, rules)
    B, S = shape.global_batch, shape.seq_len
    cache_tree = model.describe_cache(B, S)
    if window_limited and cfg.local_global_alternating and cfg.sliding_window:
        # §Perf: local-attention layers only ever read the last `window`
        # positions — shrink their cache slots accordingly.
        win = cfg.sliding_window
        cache_tree["local"] = jax.tree.map(
            lambda l: Leaf((l.shape[0], l.shape[1], win, *l.shape[3:]),
                           l.axes, l.dtype, l.scale, l.init),
            cache_tree["local"],
            is_leaf=is_leaf,
        )
    p_specs = sharding_tree(param_tree, mesh, rules)
    c_specs = sharding_tree(cache_tree, mesh, rules)
    tok_spec = rules.sharding(mesh, ("batch",), (B,))
    logits_spec = rules.sharding(mesh, ("batch", "vocab_act"), (B, cfg.vocab_size))

    def serve_step(params, cache, tokens, lengths):
        return model.decode(params, cache, tokens, lengths, ctx)

    jitted = jax.jit(
        serve_step,
        in_shardings=(p_specs, c_specs, tok_spec, tok_spec),
        out_shardings=(logits_spec, c_specs),
        donate_argnums=(1,),
    )
    args = (
        abstract(param_tree),
        abstract(cache_tree),
        jax.ShapeDtypeStruct((B,), jnp.int32),
        jax.ShapeDtypeStruct((B,), jnp.int32),
    )
    return Cell(
        arch, shape.name, "decode", jitted, args, rules,
        meta={"tokens_per_step": B},
    )


# ----------------------------------------------------------------- skip rule
def cell_skip_reason(cfg: ModelConfig, shape_name: str) -> str | None:
    """DESIGN.md §long_500k: run long-context decode only for sub-quadratic
    families (ssm / hybrid); all other shapes run for every arch."""
    if shape_name == "long_500k" and not cfg.supports_long_context:
        return (
            "skipped: full-attention arch at 524k context (assignment rule; "
            "see DESIGN.md §Arch-applicability)"
        )
    return None
