"""Training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch qwen1.5-0.5b \
        --steps 50 --reduced --ckpt-dir /tmp/ckpt

``--reduced`` swaps in the architecture's smoke-scale config so the loop
runs on CPU; omit it on real hardware. Restart the same command after a
crash (or with a different host topology) and it resumes from the newest
valid checkpoint.
"""
from __future__ import annotations

import argparse
import json

from repro.configs import get_config
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.train.data import DataConfig
from repro.train.loop import FaultInjector, TrainConfig, Trainer


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--microbatches", type=int, default=2)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--reduced", action="store_true",
                    help="smoke-scale model config (CPU-runnable)")
    ap.add_argument("--production-mesh", action="store_true",
                    help="16x16 mesh (requires 256 devices)")
    ap.add_argument("--fail-at", type=int, nargs="*", default=[],
                    help="inject a crash at these steps (fault-tolerance demo)")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    mesh = (
        make_production_mesh() if args.production_mesh else make_host_mesh()
    )
    tcfg = TrainConfig(
        steps=args.steps,
        microbatches=args.microbatches,
        lr=args.lr,
        ckpt_every=args.ckpt_every if args.ckpt_dir else 0,
        ckpt_dir=args.ckpt_dir,
    )
    data = DataConfig(
        vocab_size=cfg.vocab_size,
        seq_len=args.seq_len,
        global_batch=args.global_batch,
    )
    trainer = Trainer(cfg, tcfg, mesh, data)
    fault = FaultInjector(tuple(args.fail_at)) if args.fail_at else None

    state = trainer.resume_or_init()
    print(f"training {cfg.name} from step {state.step} to {tcfg.steps} "
          f"on mesh {dict(mesh.shape)}")
    while True:
        try:
            state = trainer.run(state, fault)
            break
        except RuntimeError as e:
            print(f"!! {e} — restarting from checkpoint")
            state = trainer.resume_or_init()
    for m in trainer.metrics:
        print(json.dumps(m))
    print(f"done at step {state.step}")


if __name__ == "__main__":
    main()
