"""Serving launcher: MORI router over DP replicas of the real JAX engine.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen1.5-0.5b \
        --replicas 2 --programs 8 --snapshot /tmp/mori_state.json

Runs reduced-scale on CPU (the production mesh path is exercised by
``repro.launch.dryrun``). ``--snapshot`` persists the control plane each
run; ``--resume`` restores it first (programs re-enter via the Waiting
queue — MORI's recompute path doubles as crash recovery).
"""
from __future__ import annotations

import argparse
from pathlib import Path

from repro.configs import get_config
from repro.core.scheduler import SchedulerConfig
from repro.dist import make_replica_set
from repro.models import Model, materialize
from repro.serving import Engine, MoriRouter
from repro.serving.state_io import restore_snapshot, save_snapshot
from repro.traces import TraceGenConfig, generate_corpus


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b")
    ap.add_argument("--scheduler", default="mori",
                    choices=["mori", "ta+o", "ta", "smg"])
    ap.add_argument("--replicas", type=int, default=2)
    ap.add_argument("--programs", type=int, default=8)
    ap.add_argument("--max-new-tokens", type=int, default=4)
    ap.add_argument("--gpu-pages", type=int, default=8,
                    help="scheduler GPU budget (pages/replica)")
    ap.add_argument("--cpu-pages", type=int, default=20)
    ap.add_argument("--snapshot", default="")
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--serial-decode", action="store_true",
                    help="pre-pump compatibility mode: run each request "
                         "to completion instead of batched decode")
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    params = materialize(Model(cfg).describe(), seed=0)
    # one rules object shared by all replicas (repro.dist invariant): a
    # program migrated between replicas lands on a byte-identical layout
    replica_set = make_replica_set(args.replicas, num_kv_heads=cfg.num_kv_heads)
    engines = [
        Engine(cfg, params, page_tokens=16, n_device_pages=72,
               n_host_pages=160, max_slots=3, max_seq=384,
               placement=placement)
        for placement in replica_set
    ]
    router = MoriRouter(
        engines,
        scheduler=args.scheduler,
        gpu_capacity_bytes=engines[0].pool.page_bytes * args.gpu_pages,  # lint: kv008-ok (GPU budget at device format)
        cpu_capacity_bytes=engines[0].pool.host_page_bytes * args.cpu_pages,
        config=SchedulerConfig(tick_interval_s=1.0),
        serial_decode=args.serial_decode,
    )
    if args.resume and args.snapshot and Path(args.snapshot).exists():
        counters = restore_snapshot(router, args.snapshot)
        print(f"resumed control plane: {counters}")

    corpus = generate_corpus(
        args.programs, seed=1,
        cfg=TraceGenConfig(
            min_steps=4, mean_steps=7, max_steps=9,
            initial_context_mean=900, max_context=2400,
            long_median_s=45.0, busy_calls_mean=3.0, idle_calls_mean=3.0,
        ),
    )
    print(f"serving {len(corpus)} programs on {args.replicas} replicas "
          f"({args.scheduler})")
    m = router.replay(corpus, vocab_size=cfg.vocab_size,
                      max_new_tokens=args.max_new_tokens)
    print(f"steps {m.steps_completed}  tokens {m.tokens_generated}  "
          f"hit {m.cache_hit_rate:.1%}  offl {m.offloaded_pages}  "
          f"reload {m.reloaded_pages}  gated {m.gated_events}")
    print(f"decode dispatches {m.pump_steps}  batch occupancy "
          f"{m.mean_batch_occupancy:.2f} (peak {m.peak_live_slots})  "
          f"slot wait {m.slot_wait_s:.1f}s  overlap steps "
          f"{m.overlap_decode_steps}")
    if args.snapshot:
        save_snapshot(router, args.snapshot)
        print(f"control plane snapshot -> {args.snapshot}")


if __name__ == "__main__":
    main()
