"""Production mesh builders (deliverable e).

A FUNCTION, not a module-level constant, so importing this module never
touches jax device state (the dry-run must set XLA_FLAGS before first init).
"""
from __future__ import annotations

import jax


def _make_mesh(shape, axes):
    # axis_types landed after jax 0.4.x; Auto is the default there anyway
    if hasattr(jax.sharding, "AxisType"):
        return jax.make_mesh(
            shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes)
        )
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; multi_pod adds the 2-pod leading axis."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes)


def make_host_mesh():
    """Single-device mesh for tests/examples on CPU."""
    return _make_mesh((1, 1), ("data", "model"))
