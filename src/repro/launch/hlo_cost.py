"""Call-graph-aware cost extraction from optimized (post-SPMD) HLO text.

XLA's ``compiled.cost_analysis()`` counts a ``while`` body ONCE, so any
scan-over-layers model is undercounted by ~num_layers x (verified in this
repo: a 10-iteration scan of matmuls reports the FLOPs of one). This module
re-derives the roofline terms correctly:

1. parse the HLO module into computations and their ops (two-phase, so
   fusion *internals* are known before call sites are costed);
2. per computation, tally
   * dot FLOPs (2 x out_elems x contraction size, from operand shapes),
   * HBM bytes with **operand utilization**: a fusion parameter whose only
     in-fusion users are (dynamic-)slices counts the sliced bytes, not the
     full buffer (the layer-scan slices one layer from stacked weights/KV —
     charging the full stack per iteration overcounts ~num_layers x), and a
     fusion rooted at dynamic-update-slice writes the update in place, not
     the whole aliased loop carry;
   * collective wire bytes (ring formulas);
3. walk the call graph from ENTRY, multiplying while-loop bodies by their
   trip counts (largest integer constant in the condition region — exact
   for lax.scan/fori_loop lowerings).

Two recorded adjustments (both default-on for the TPU-target baseline):

* ``bf16_normalize`` — XLA:CPU's FloatNormalization pass promotes bf16
  compute (and hoisted weight/KV copies) to f32; on the TPU target these
  stay bf16, so f32 tensors are counted at 2 bytes/elem. Raw bytes are
  reported alongside.
* ``vmem_budget`` (default 0 = off) — §Perf lever modeling the Pallas
  kernels: tensors produced AND consumed inside one computation whose size
  is <= the budget stay in VMEM and contribute no HBM traffic. Off for the
  paper-faithful baseline (the pure-jnp path does materialize them).
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COMP_HEADER_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->\s*.+\{\s*$")
_OP_RE = re.compile(r"^\s+(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.+?)\s+([\w\-]+)\(")
_ROOT_RE = re.compile(r"^\s+ROOT\s+%?([\w.\-]+)\s*=")
_CALLS_RE = re.compile(r"calls=%?([\w.\-]+)")
_WHILE_RE = re.compile(r"condition=%?([\w.\-]+),\s*body=%?([\w.\-]+)")
_CONST_RE = re.compile(r"constant\((\d+)\)")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")

COLLECTIVES = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute",
)

#: ops whose in-fusion consumption of a parameter touches only their output
_SLICE_OPS = ("dynamic-slice", "slice", "gather")

#: in-fusion ops that forward their (first) operand without HBM traffic on
#: the TPU target: dtype converts are register ops (and on CPU are float-
#: normalization artifacts), bitcast/reshape are free, copies fuse.
_IDENTITY_OPS = ("convert", "bitcast", "reshape", "copy", "transpose")

#: control/metadata ops whose "output" isn't data traffic
_FREE_OPS = (
    "parameter", "constant", "iota", "get-tuple-element", "tuple", "bitcast",
    "after-all", "partition-id", "replica-id",
)


def _prod(dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d.strip():
            n *= int(d)
    return n


@dataclass
class _Op:
    name: str
    op: str
    sig: str
    line: str
    operands: list[str]
    is_root: bool = False


@dataclass
class _Comp:
    name: str
    is_entry: bool = False
    ops: list[_Op] = field(default_factory=list)
    max_const: int = 1
    # filled by _cost_computation:
    flops: float = 0.0
    bytes_: float = 0.0
    raw_bytes: float = 0.0
    wire: dict[str, float] = field(default_factory=dict)
    coll_counts: dict[str, int] = field(default_factory=dict)
    calls: list[tuple[str, float]] = field(default_factory=list)


@dataclass
class HloCost:
    flops: float
    hbm_bytes: float
    hbm_bytes_raw: float
    wire_bytes: dict[str, float]
    collective_counts: dict[str, int]

    @property
    def total_wire_bytes(self) -> float:
        return sum(self.wire_bytes.values())

    def to_dict(self) -> dict:
        return {
            "flops": self.flops,
            "hbm_bytes": self.hbm_bytes,
            "hbm_bytes_raw": self.hbm_bytes_raw,
            "wire_bytes": {k: round(v) for k, v in self.wire_bytes.items()},
            "collective_counts": self.collective_counts,
            "total_wire_bytes": round(self.total_wire_bytes),
        }


#: ``{output_tuple_index}: (param_number, {param_path}[, kind])`` pairs in
#: the module header — what XLA actually honored out of donate_argnums
_ALIAS_PAIR_RE = re.compile(r"\{\s*([\d,\s]*)\}:\s*\(\s*(\d+)\s*,\s*\{([\d,\s]*)\}")


def parse_input_output_alias(hlo_text: str) -> list[tuple[int, int]]:
    """``(output_index, param_index)`` pairs from the compiled module's
    ``input_output_alias`` header — the ground truth for whether a
    ``donate_argnums`` request survived compilation.  A donated buffer
    XLA could not reuse (dtype/shape mismatch with every output) simply
    has no pair here; the jitaudit donation verifier diffs this list
    against the donation marks in the lowered StableHLO.  Only
    single-level output-tuple indices are expected (jit flattens
    pytrees); deeper paths keep their leading index."""
    start = hlo_text.find("input_output_alias={")
    if start < 0:
        return []
    # the alias map nests braces ({out_path}: (param, {param_path})), so
    # extract the body with a balance scan rather than a regex
    i = start + len("input_output_alias=")
    depth, body_start, body = 0, i + 1, ""
    for j in range(i, len(hlo_text)):
        ch = hlo_text[j]
        if ch == "{":
            depth += 1
        elif ch == "}":
            depth -= 1
            if depth == 0:
                body = hlo_text[body_start:j]
                break
    out: list[tuple[int, int]] = []
    for pair in _ALIAS_PAIR_RE.finditer(body):
        out_path = [int(x) for x in pair.group(1).split(",") if x.strip()]
        out.append((out_path[0] if out_path else 0, int(pair.group(2))))
    return out


def _parse(hlo_text: str) -> tuple[dict[str, _Comp], str | None]:
    comps: dict[str, _Comp] = {}
    entry = None
    cur: _Comp | None = None
    for raw in hlo_text.splitlines():
        header = _COMP_HEADER_RE.match(raw)
        if header:
            cur = _Comp(name=header.group(1), is_entry=raw.startswith("ENTRY"))
            comps[cur.name] = cur
            if cur.is_entry:
                entry = cur.name
            continue
        if cur is None:
            continue
        for c in _CONST_RE.finditer(raw):
            cur.max_const = max(cur.max_const, int(c.group(1)))
        m = _OP_RE.match(raw)
        if not m:
            continue
        name, sig, op = m.group(1), m.group(2), m.group(3)
        after = raw.split(f"{op}(", 1)
        # strip attribute tail (calls=..., sharding=...) so operand parsing
        # doesn't pick up computation names
        arg_str = after[1] if len(after) > 1 else ""
        depth, cut = 1, len(arg_str)
        for i, ch in enumerate(arg_str):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    cut = i
                    break
        operands = _OPERAND_RE.findall(arg_str[:cut])
        cur.ops.append(
            _Op(name, op, sig, raw, operands, is_root=bool(_ROOT_RE.match(raw)))
        )
    return comps, entry


class _Coster:
    def __init__(
        self, comps: dict[str, _Comp], *, dtype_bytes, vmem_budget: int,
        assume_donation: bool = False,
    ):
        self.comps = comps
        self.dtype_bytes = dtype_bytes
        self.vmem = vmem_budget
        self.assume_donation = assume_donation
        self.raw_dtype_bytes = _DTYPE_BYTES

    def shape_bytes(self, sig: str, *, raw=False) -> int:
        table = self.raw_dtype_bytes if raw else self.dtype_bytes
        return sum(
            _prod(dims) * table.get(dt, 0) for dt, dims in _SHAPE_RE.findall(sig)
        )

    def first_dims(self, sig: str) -> list[int] | None:
        m = _SHAPE_RE.search(sig)
        if not m:
            return None
        return [int(d) for d in m.group(2).split(",") if d.strip()]

    # -------------------------------------------------- fusion introspection
    def _effective_users(
        self, comp: _Comp, start: str
    ) -> list[tuple[_Op, str]]:
        """Transitive non-identity users of a value inside a fusion.

        Identity ops (convert/bitcast/...) forward the value; the returned
        pairs are (consuming op, immediate value name it consumed), so the
        caller can tell which operand slot the value reached.
        """
        users_of: dict[str, list[_Op]] = {}
        for o in comp.ops:
            for ref in o.operands:
                users_of.setdefault(ref, []).append(o)
        out: list[tuple[_Op, str]] = []
        frontier = [start]
        seen = set()
        while frontier:
            v = frontier.pop()
            if v in seen:
                continue
            seen.add(v)
            for u in users_of.get(v, []):
                if u.op in _IDENTITY_OPS:
                    frontier.append(u.name)
                else:
                    out.append((u, v))
        return out

    def _resolve_identity(self, comp: _Comp, name: str) -> _Op | None:
        """Follow identity chains backwards to the originating op."""
        by_name = {o.name: o for o in comp.ops}
        o = by_name.get(name)
        while o is not None and o.op in _IDENTITY_OPS and o.operands:
            nxt = by_name.get(o.operands[0])
            if nxt is None:
                break
            o = nxt
        return o

    def _update_bytes(self, comp: _Comp, op: _Op) -> tuple[float, float]:
        """Bytes of the update operand of a dynamic-update-slice (operand 1)
        or scatter (operand 2) — the in-place-touched region."""
        idx = 1 if op.op == "dynamic-update-slice" else 2
        if len(op.operands) <= idx:
            return self.shape_bytes(op.sig), self.shape_bytes(op.sig, raw=True)
        upd = self._resolve_identity(comp, op.operands[idx])
        if upd is not None:
            return self.shape_bytes(upd.sig), self.shape_bytes(upd.sig, raw=True)
        m = re.search(
            rf"(\w+)\[([\d,]*)\][^%]*%{re.escape(op.operands[idx])}\b", op.line
        )
        if m:
            sig = f"{m.group(1)}[{m.group(2)}]"
            return self.shape_bytes(sig), self.shape_bytes(sig, raw=True)
        return self.shape_bytes(op.sig), self.shape_bytes(op.sig, raw=True)

    def fusion_param_access(self, comp: _Comp) -> tuple[list[float], list[float]]:
        """Per-parameter (normalized, raw) bytes actually read inside a fusion.

        Identity chains (convert/bitcast/...) are seen through. A parameter
        whose effective users are all (dynamic-)slices is charged the union
        of its users' outputs; one that only feeds the aliased operand of a
        dynamic-update-slice is charged the update region (in-place write —
        the untouched bytes are never read); anything else reads the full
        buffer.
        """
        params: dict[int, _Op] = {}
        for o in comp.ops:
            if o.op == "parameter":
                idx = int(re.search(r"parameter\((\d+)\)", o.line).group(1))
                params[idx] = o
        acc_n, acc_r = [], []
        for idx in range(len(params)):
            p = params.get(idx)
            if p is None:
                acc_n.append(0.0)
                acc_r.append(0.0)
                continue
            full_n = self.shape_bytes(p.sig)
            full_r = self.shape_bytes(p.sig, raw=True)
            us = self._effective_users(comp, p.name)
            n = r = 0.0
            exceeded = not us
            for u, via in us:
                if u.op in _SLICE_OPS:
                    n += self.shape_bytes(u.sig)
                    r += self.shape_bytes(u.sig, raw=True)
                elif u.op in ("dynamic-update-slice", "scatter") and u.operands and (
                    u.operands[0] == via
                ):
                    # in-place update: only the touched region is read
                    dn, dr = self._update_bytes(comp, u)
                    n += dn
                    r += dr
                else:
                    exceeded = True
                    break
            if exceeded:
                acc_n.append(full_n)
                acc_r.append(full_r)
            else:
                acc_n.append(min(n, full_n))
                acc_r.append(min(r, full_r))
        return acc_n, acc_r

    def fusion_write_bytes(self, comp: _Comp) -> tuple[float, float]:
        """Bytes a fusion writes: its root's output, except a root
        dynamic-update-slice (possibly behind identity converts) writes only
        the update slice — the buffer is aliased in place (loop carries
        always are)."""
        root = next((o for o in comp.ops if o.is_root), None)
        if root is None:
            return 0.0, 0.0
        eff = root
        if eff.op in _IDENTITY_OPS:
            resolved = self._resolve_identity(comp, eff.name)
            if resolved is not None:
                eff = resolved
        if eff.op in ("dynamic-update-slice", "scatter"):
            return self._update_bytes(comp, eff)
        return self.shape_bytes(root.sig), self.shape_bytes(root.sig, raw=True)

    def fusion_is_shim(self, comp: _Comp) -> bool:
        """True for fusions containing only identity/metadata ops — dtype
        converts and layout shuffles that on the TPU target either don't
        exist (f32 promotion of bf16 compute is a CPU FloatNormalization
        artifact) or propagate into the consumer's layout. Their consumers
        charge the operand read themselves (dot operands, fusion params)."""
        return all(
            o.op in _IDENTITY_OPS or o.op in _FREE_OPS for o in comp.ops
        )

    def fusion_is_slice_shim(self, comp: _Comp) -> bool:
        """True for fusions of only slice+identity ops (e.g. the layer-scan's
        ``convert(dynamic-slice(stack, i))``). The slice READ is real HBM
        traffic (charged via param access); the materialized WRITE is a CPU
        artifact — on TPU the slice fuses into its consumer as an operand."""
        return all(
            o.op in _IDENTITY_OPS or o.op in _FREE_OPS or o.op in _SLICE_OPS
            for o in comp.ops
        )

    def fusion_is_zero_init(self, comp: _Comp) -> bool:
        """True for broadcast-of-scalar fusions (fresh output buffers for
        non-aliased loop carries). With donated inputs the TPU runtime
        aliases these away; counted only without ``assume_donation``."""
        return all(
            o.op in _FREE_OPS or o.op == "broadcast" for o in comp.ops
        ) and any(o.op == "broadcast" for o in comp.ops)

    # --------------------------------------------------------- computation
    def cost_computation(self, comp: _Comp) -> None:
        produced_small: set[str] = set()   # VMEM-resident (lever on)
        symtab: dict[str, tuple[float, float, list[int] | None]] = {}

        def op_out(o: _Op) -> tuple[float, float]:
            return self.shape_bytes(o.sig), self.shape_bytes(o.sig, raw=True)

        for o in comp.ops:
            out_n, out_r = op_out(o)
            symtab[o.name] = (out_n, out_r, self.first_dims(o.sig))
            if (
                self.vmem
                and o.op not in ("parameter",)
                and not o.is_root
                and out_r <= self.vmem
            ):
                produced_small.add(o.name)

            if o.op == "while":
                w = _WHILE_RE.search(o.line)
                if w:
                    comp.calls.append(
                        ("__while__:" + w.group(1) + ":" + w.group(2), 1.0)
                    )
                continue
            cm = _CALLS_RE.search(o.line)
            if cm:
                callee_name = cm.group(1)
                if o.op == "fusion":
                    callee = self.comps.get(callee_name)
                    if callee is not None:
                        acc_n, acc_r = self.fusion_param_access(callee)
                        shim = self.fusion_is_shim(callee)
                        zero_init = (
                            self.assume_donation
                            and comp.is_entry
                            and self.fusion_is_zero_init(callee)
                        )
                        for i, opnd in enumerate(o.operands[: len(acc_n)]):
                            if opnd in produced_small:
                                continue
                            if not (shim or zero_init):
                                comp.bytes_ += acc_n[i]
                            comp.raw_bytes += acc_r[i]
                        w_n, w_r = self.fusion_write_bytes(callee)
                        if not (self.vmem and not o.is_root and w_r <= self.vmem):
                            if not (shim or zero_init
                                    or self.fusion_is_slice_shim(callee)):
                                comp.bytes_ += w_n
                            comp.raw_bytes += w_r
                    # fusion internals are VMEM; no call edge for bytes/flops
                    # EXCEPT dots can appear inside fusions on some backends:
                    self._fusion_internal_flops(callee_name, comp)
                else:
                    comp.calls.append((callee_name, 1.0))
                continue
            if o.op == "conditional":
                for cal in re.findall(
                    r"(?:true_computation|false_computation|"
                    r"branch_computations)=\{?%?([\w.\-{}, ]+)",
                    o.line,
                ):
                    for c2 in re.findall(r"[\w.\-]+", cal):
                        comp.calls.append((c2, 1.0))
                continue

            # ---------------------------------------------------- leaf ops
            if o.op == "dot":
                contract = 1
                cmatch = _CONTRACT_RE.search(o.line)
                lhs_dims = None
                if o.operands:
                    rec = symtab.get(o.operands[0])
                    lhs_dims = rec[2] if rec else None
                    if lhs_dims is None:
                        lhs_dims = _op_dims_from_line(o.line, o.operands[0])
                if cmatch and lhs_dims:
                    for idx in cmatch.group(1).split(","):
                        if idx.strip():
                            i = int(idx)
                            if i < len(lhs_dims):
                                contract *= lhs_dims[i]
                out_elems = _prod_dims(o.sig)
                comp.flops += 2.0 * out_elems * max(1, contract)
                if not (self.vmem and not o.is_root and out_r <= self.vmem):
                    comp.bytes_ += out_n
                    comp.raw_bytes += out_r
                for opnd in o.operands[:2]:
                    if opnd in produced_small:
                        continue
                    rec = symtab.get(opnd)
                    if rec:
                        comp.bytes_ += rec[0]
                        comp.raw_bytes += rec[1]
                continue

            matched = False
            for coll in COLLECTIVES:
                if o.op.startswith(coll):
                    matched = True
                    if o.op.endswith("-done"):
                        break
                    n_b, r_b = out_n, out_r
                    if o.op.endswith("-start") and "(" in o.sig:
                        n_b //= 2
                        r_b //= 2
                    n = _groups_n(o.line)
                    frac = (n - 1) / n
                    if coll == "all-reduce":
                        wire = 2 * frac * n_b
                    elif coll == "all-gather":
                        wire = frac * n_b
                    elif coll == "reduce-scatter":
                        wire = frac * n_b * n
                    elif coll == "all-to-all":
                        wire = frac * n_b
                    else:
                        wire = float(n_b)
                    comp.wire[coll] = comp.wire.get(coll, 0.0) + wire
                    comp.coll_counts[coll] = comp.coll_counts.get(coll, 0) + 1
                    comp.bytes_ += 2 * n_b
                    comp.raw_bytes += 2 * r_b
                    break
            if matched:
                continue

            if o.op == "dynamic-update-slice":
                # in-place write: update read + write
                upd = symtab.get(o.operands[1]) if len(o.operands) > 1 else None
                if upd:
                    comp.bytes_ += 2 * upd[0]
                    comp.raw_bytes += 2 * upd[1]
                continue
            if o.op in _SLICE_OPS:
                if o.operands and o.operands[0] in produced_small:
                    continue
                comp.bytes_ += 2 * out_n
                comp.raw_bytes += 2 * out_r
                continue
            if o.op == "scatter":
                un, ur = self._update_bytes(comp, o)
                comp.bytes_ += 2 * un
                comp.raw_bytes += 2 * ur
                continue
            if o.op in ("copy", "reduce", "concatenate", "custom-call",
                        "convert", "transpose", "reshape", "broadcast", "pad"):
                # real data movement when materialized at top level
                if o.op in ("copy", "reduce", "concatenate", "custom-call"):
                    if (
                        o.op == "copy"
                        and self.assume_donation
                        and comp.is_entry
                    ):
                        # donated-input aliasing elides I/O round-trip
                        # copies of loop carries on the TPU target
                        comp.raw_bytes += 2 * out_r
                        continue
                    if o.operands and all(x in produced_small for x in o.operands if x in symtab):
                        continue
                    comp.bytes_ += 2 * out_n
                    comp.raw_bytes += 2 * out_r
                continue
            # remaining elementwise/metadata ops: fused on the TPU target

    def _fusion_internal_flops(self, callee_name: str, into: _Comp) -> None:
        callee = self.comps.get(callee_name)
        if callee is None:
            return
        symtab = {o.name: self.first_dims(o.sig) for o in callee.ops}
        for o in callee.ops:
            if o.op != "dot":
                continue
            contract = 1
            cmatch = _CONTRACT_RE.search(o.line)
            lhs_dims = symtab.get(o.operands[0]) if o.operands else None
            if lhs_dims is None and o.operands:
                lhs_dims = _op_dims_from_line(o.line, o.operands[0])
            if cmatch and lhs_dims:
                for idx in cmatch.group(1).split(","):
                    if idx.strip():
                        i = int(idx)
                        if i < len(lhs_dims):
                            contract *= lhs_dims[i]
            into.flops += 2.0 * _prod_dims(o.sig) * max(1, contract)


def analyze(
    hlo_text: str,
    *,
    vmem_budget: int = 0,
    bf16_normalize: bool = True,
    assume_donation: bool = False,
) -> HloCost:
    comps, entry = _parse(hlo_text)
    dtype_bytes = dict(_DTYPE_BYTES)
    if bf16_normalize:
        dtype_bytes["f32"] = 2
    coster = _Coster(
        comps, dtype_bytes=dtype_bytes, vmem_budget=vmem_budget,
        assume_donation=assume_donation,
    )
    for comp in comps.values():
        coster.cost_computation(comp)

    def resolve(name: str, mult: float, seen: tuple):
        if name.startswith("__while__:"):
            _, cond, body = name.split(":")
            trips = max(1, comps.get(cond, _Comp(cond)).max_const)
            r1 = resolve(cond, mult * trips, seen)
            r2 = resolve(body, mult * trips, seen)
            return tuple(
                _merge(a, b) if isinstance(a, dict) else a + b
                for a, b in zip(r1, r2)
            )
        comp = comps.get(name)
        if comp is None or name in seen:
            return 0.0, 0.0, 0.0, {}, {}
        seen = seen + (name,)
        f = comp.flops * mult
        b = comp.bytes_ * mult
        rb = comp.raw_bytes * mult
        w = {k: v * mult for k, v in comp.wire.items()}
        c = {k: int(v * mult) for k, v in comp.coll_counts.items()}
        for callee, m2 in comp.calls:
            f2, b2, rb2, w2, c2 = resolve(callee, mult * m2, seen)
            f, b, rb = f + f2, b + b2, rb + rb2
            w, c = _merge(w, w2), _merge_i(c, c2)
        return f, b, rb, w, c

    if entry is None:
        return HloCost(0.0, 0.0, 0.0, {}, {})
    f, b, rb, w, c = resolve(entry, 1.0, ())
    return HloCost(f, b, rb, w, c)


def _groups_n(line: str) -> int:
    m = re.search(r"replica_groups=\{\{([\d,]+)\}", line)
    if m:
        return max(2, len(m.group(1).split(",")))
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]", line)
    if m:
        return max(2, int(m.group(2)))
    return 2


def _merge(a: dict, b: dict) -> dict:
    out = dict(a)
    for k, v in b.items():
        out[k] = out.get(k, 0.0) + v
    return out


def _merge_i(a: dict, b: dict) -> dict:
    out = dict(a)
    for k, v in b.items():
        out[k] = out.get(k, 0) + v
    return out


def _prod_dims(sig: str) -> int:
    m = _SHAPE_RE.search(sig)
    return _prod(m.group(2)) if m else 0


def _op_dims_from_line(line: str, operand: str) -> list[int] | None:
    """Dims of %operand as written inline in the dot line (f32[a,b] %name)."""
    m = re.search(rf"(\w+)\[([\d,]*)\][^%]*%{re.escape(operand)}\b", line)
    if not m:
        return None
    return [int(d) for d in m.group(2).split(",") if d.strip()]
