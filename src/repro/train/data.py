"""Deterministic, resumable token pipeline.

Production shape without production data: batches are generated from a
counter-based hash (stateless — ``batch_at(step)`` is pure), so

* any host can produce exactly its shard of any step (multi-host friendly,
  no data server in the loop),
* resume-after-crash needs only the step counter from the checkpoint
  manifest (no iterator state files),
* two runs with the same seed see bit-identical data regardless of
  restarts, host count, or prefetch depth.

Documents are variable-length (zipf-ish) and packed into fixed ``seq_len``
rows with cross-document attention breaks marked by a separator token —
the same packing discipline a real corpus pipeline needs.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

SEP = 0  # document separator / padding id


@dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    mean_doc_len: int = 512


class TokenPipeline:
    """Counter-based deterministic batches of packed documents."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg

    def _row(self, step: int, row: int) -> np.ndarray:
        cfg = self.cfg
        # one RNG stream per (step, row): cheap, order-independent
        rng = np.random.default_rng(
            np.uint64(cfg.seed) * np.uint64(0x9E3779B97F4A7C15)
            + np.uint64(step) * np.uint64(1_000_003)
            + np.uint64(row)
        )
        out = np.empty(cfg.seq_len + 1, dtype=np.int32)
        pos = 0
        while pos < out.size:
            doc_len = int(rng.exponential(cfg.mean_doc_len)) + 8
            take = min(doc_len, out.size - pos)
            # zipf-distributed ids: natural-language-like unigram skew, so
            # the loss has learnable structure (uniform ids would start AT
            # the optimum ln V)
            ids = rng.zipf(1.3, size=take)
            out[pos : pos + take] = (ids % (cfg.vocab_size - 1) + 1).astype(
                np.int32
            )
            pos += take
            if pos < out.size:
                out[pos] = SEP
                pos += 1
        return out

    def batch_at(
        self, step: int, *, host_id: int = 0, num_hosts: int = 1
    ) -> dict[str, np.ndarray]:
        """The [local_batch, seq_len+1] token block for ``step`` on this host.

        Rows are striped across hosts so the global batch is the
        concatenation of per-host shards in host order.
        """
        cfg = self.cfg
        assert cfg.global_batch % num_hosts == 0, (cfg.global_batch, num_hosts)
        local = cfg.global_batch // num_hosts
        rows = [self._row(step, host_id * local + r) for r in range(local)]
        return {"tokens": np.stack(rows)}

    def batches(self, start_step: int = 0, *, host_id: int = 0, num_hosts: int = 1):
        step = start_step
        while True:
            yield step, self.batch_at(step, host_id=host_id, num_hosts=num_hosts)
            step += 1
