"""AdamW in pure JAX (pytree-wise), with optional bf16 moments for the
giant MoEs (arctic-480b) so optimizer state fits v5e HBM budgets."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.params import Leaf, is_leaf


def describe_opt_state(param_tree, bf16_moments: bool = False) -> dict:
    """Leaf descriptors for the optimizer state (mirrors param shardings)."""
    mdtype = jnp.bfloat16 if bf16_moments else jnp.float32

    def mom(l: Leaf) -> Leaf:
        return Leaf(l.shape, l.axes, mdtype, init="zeros")

    return {
        "m": jax.tree.map(mom, param_tree, is_leaf=is_leaf),
        "v": jax.tree.map(mom, param_tree, is_leaf=is_leaf),
        "count": Leaf((), (), jnp.int32, init="zeros"),
    }


def adamw_update(
    grads,
    opt_state,
    params,
    *,
    lr: float = 3e-4,
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.1,
):
    count = opt_state["count"] + 1
    c = count.astype(jnp.float32)
    bc1 = 1.0 - b1 ** c
    bc2 = 1.0 - b2 ** c

    def upd(p, g, m, v):
        gf = g.astype(jnp.float32)
        m_new = b1 * m.astype(jnp.float32) + (1 - b1) * gf
        v_new = b2 * v.astype(jnp.float32) + (1 - b2) * gf * gf
        step = (m_new / bc1) / (jnp.sqrt(v_new / bc2) + eps)
        p_new = p.astype(jnp.float32) - lr * (step + weight_decay * p.astype(jnp.float32))
        return p_new.astype(p.dtype), m_new.astype(m.dtype), v_new.astype(v.dtype)

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(opt_state["m"])
    flat_v = treedef.flatten_up_to(opt_state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_p, {"m": new_m, "v": new_v, "count": count}
