"""Training loop: grad accumulation, checkpoint/restart, fault injection,
elastic re-mesh.

The loop is the train-side counterpart of the serving engine: the same
model zoo, sharding rules, and optimizer as the dry-run's ``train_step``,
driven end-to-end at reduced scale on CPU (examples/tests) and lowered
unchanged on the production mesh.

Fault-tolerance model (the 1000-node story, exercised in tests):

* every ``ckpt_every`` steps the full (params, opt, step) state is written
  atomically (see ``repro.train.checkpoint``);
* a crash at ANY point restarts from the newest valid checkpoint — data
  batches are counter-derived so the resumed run consumes exactly the
  batches the crashed run would have (bit-identical trajectory, verified
  in tests/test_train.py);
* ``FaultInjector`` raises at configurable steps to exercise that path;
* restart may use a DIFFERENT mesh (fewer hosts after a failure, more
  after scale-up): ``Trainer.restore`` re-shards the checkpoint through
  the new mesh's shardings (elastic re-mesh).
"""
from __future__ import annotations

import time
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.dist.sharding import make_train_rules
from repro.models import Model, ModelConfig, ShardCtx
from repro.models.params import abstract, is_leaf, materialize, sharding_tree
from repro.train import checkpoint as ckpt_lib
from repro.train.data import DataConfig, TokenPipeline
from repro.train.optimizer import adamw_update, describe_opt_state


class FaultInjector:
    """Raises a simulated node failure at the given global steps."""

    def __init__(self, fail_at: tuple[int, ...] = ()):
        self.fail_at = set(fail_at)
        self.fired: set[int] = set()

    def check(self, step: int) -> None:
        if step in self.fail_at and step not in self.fired:
            self.fired.add(step)
            raise RuntimeError(f"injected node failure at step {step}")


@dataclass
class TrainConfig:
    steps: int = 100
    microbatches: int = 1          # grad accumulation factor
    lr: float = 3e-4
    ckpt_every: int = 0            # 0 = no checkpointing
    ckpt_dir: str = ""
    keep: int = 3
    log_every: int = 10
    seed: int = 0


@dataclass
class TrainState:
    params: object
    opt: object
    step: int = 0


class Trainer:
    """Builds the jitted accumulating train step on an arbitrary mesh."""

    def __init__(self, cfg: ModelConfig, tcfg: TrainConfig, mesh,
                 data: DataConfig | None = None):
        self.cfg = cfg
        self.tcfg = tcfg
        self.mesh = mesh
        self.model = Model(cfg)
        self.rules = make_train_rules(mesh)
        self.ctx = ShardCtx(mesh, self.rules)
        self.param_tree = self.model.describe()
        self.opt_tree = describe_opt_state(
            self.param_tree, bf16_moments=cfg.bf16_moments
        )
        self.data_cfg = data
        self.pipeline = TokenPipeline(data) if data else None
        self._jit = None
        self.metrics: list[dict] = []

    # ------------------------------------------------------------- state
    def init_state(self) -> TrainState:
        params = materialize(self.param_tree, seed=self.tcfg.seed)
        opt = materialize(self.opt_tree)
        p_sh = sharding_tree(self.param_tree, self.mesh, self.rules)
        o_sh = sharding_tree(self.opt_tree, self.mesh, self.rules)
        params = jax.tree.map(jax.device_put, params, p_sh)
        opt = jax.tree.map(jax.device_put, opt, o_sh)
        return TrainState(params, opt, 0)

    def shardings(self):
        return (
            sharding_tree(self.param_tree, self.mesh, self.rules),
            sharding_tree(self.opt_tree, self.mesh, self.rules),
        )

    # -------------------------------------------------------------- step
    def build_step(self):
        """jitted (params, opt, tokens[M, B/M, S+1]) -> (params, opt, loss)
        with M sequential microbatches (grad accumulation via lax.scan)."""
        if self._jit is not None:
            return self._jit
        model, ctx, tcfg = self.model, self.ctx, self.tcfg

        def loss_fn(p, tokens):
            return model.loss(p, {"tokens": tokens}, ctx)

        def train_step(params, opt, tokens):
            def micro(acc, tok):
                (loss, _), g = jax.value_and_grad(loss_fn, has_aux=True)(
                    params, tok
                )
                acc = jax.tree.map(jnp.add, acc, g)
                return acc, loss

            zeros = jax.tree.map(
                lambda l: jnp.zeros(l.shape, jnp.float32),
                self.param_tree, is_leaf=is_leaf,
            )
            grads, losses = jax.lax.scan(micro, zeros, tokens)
            grads = jax.tree.map(lambda g: g / tokens.shape[0], grads)
            new_p, new_opt = adamw_update(grads, opt, params, lr=tcfg.lr)
            return new_p, new_opt, losses.mean()

        p_sh, o_sh = self.shardings()
        tok_sh = NamedSharding(
            self.mesh,
            self.rules.spec(self.mesh, (None, "batch", None)),
        )
        self._jit = jax.jit(
            train_step,
            in_shardings=(p_sh, o_sh, tok_sh),
            out_shardings=(p_sh, o_sh, NamedSharding(self.mesh, P())),
            donate_argnums=(0, 1),
        )
        return self._jit

    def _tokens_for(self, step: int) -> np.ndarray:
        batch = self.pipeline.batch_at(step)["tokens"]
        m = self.tcfg.microbatches
        b = batch.shape[0]
        assert b % m == 0, (b, m)
        return batch.reshape(m, b // m, -1)

    # -------------------------------------------------------------- run
    def run(self, state: TrainState | None = None,
            fault: FaultInjector | None = None) -> TrainState:
        """Run to ``tcfg.steps``; checkpoint periodically; propagate injected
        faults after making the state durable (caller restarts via
        ``resume_or_init``)."""
        tcfg = self.tcfg
        if state is None:
            state = self.resume_or_init()
        step_fn = self.build_step()
        t0 = time.time()
        while state.step < tcfg.steps:
            if fault is not None:
                fault.check(state.step)
            tokens = self._tokens_for(state.step)
            state.params, state.opt, loss = step_fn(
                state.params, state.opt, tokens
            )
            state.step += 1
            if tcfg.log_every and state.step % tcfg.log_every == 0:
                loss_v = float(loss)
                self.metrics.append(
                    {"step": state.step, "loss": loss_v,
                     "wall_s": round(time.time() - t0, 2)}
                )
            if tcfg.ckpt_every and state.step % tcfg.ckpt_every == 0:
                self.save(state)
        if tcfg.ckpt_every:
            self.save(state)
        return state

    # ------------------------------------------------------ checkpointing
    def save(self, state: TrainState) -> None:
        ckpt_lib.save(
            self.tcfg.ckpt_dir,
            state.step,
            {"params": state.params, "opt": state.opt},
            extra={"model": self.cfg.name, "seed": self.tcfg.seed},
            keep=self.tcfg.keep,
        )

    def resume_or_init(self) -> TrainState:
        """Resume from the newest valid checkpoint, else fresh init. Works
        across mesh changes: the restore re-shards onto self.mesh."""
        if self.tcfg.ckpt_dir and ckpt_lib.latest_step(self.tcfg.ckpt_dir) is not None:
            p_sh, o_sh = self.shardings()
            ref = {
                "params": abstract(self.param_tree),
                "opt": abstract(self.opt_tree),
            }
            tree, step, _ = ckpt_lib.restore(
                self.tcfg.ckpt_dir, ref,
                shardings={"params": p_sh, "opt": o_sh},
            )
            return TrainState(tree["params"], tree["opt"], step)
        return self.init_state()
