"""Atomic, resumable, multi-host checkpointing.

Layout (one directory per step)::

    <dir>/step_000420/
        manifest.json        # step, tree structure, shard list, checksums
        host00.npz           # this host's param/opt shards (flat leaf dict)
    <dir>/LATEST             # atomic pointer file -> "step_000420"

Guarantees engineered for the 1000-node story:

* **Atomicity** — shards are written to ``<step>.tmp/`` and the directory is
  renamed into place after the manifest fsync; LATEST is updated by
  write-to-temp + ``os.replace`` (POSIX-atomic). A crash at any point
  leaves either the old or the new checkpoint fully intact.
* **Integrity** — every shard carries a CRC32 in the manifest; a bit-rotted
  or truncated shard is detected at restore and the previous checkpoint is
  used instead.
* **Elasticity** — shards store *unsharded leaf* arrays per host slice
  along the data axis only when the leaf is host-partitioned; restoring
  onto a different mesh re-shards through ``jax.device_put`` with the new
  sharding, so a shrunk/grown mesh restarts from the same files
  (``restore(..., shardings=new)``).
* **Retention** — ``keep`` newest checkpoints survive; older ones are
  removed only after a newer one is durable.
"""
from __future__ import annotations

import json
import os
import shutil
import zlib
from pathlib import Path

import jax
import numpy as np


def _flatten(tree) -> dict[str, np.ndarray]:
    """Leaf dict with npz-safe dtypes: non-native dtypes (bfloat16 via
    ml_dtypes) are widened to float32 on disk; ``restore`` casts back to the
    reference tree's dtype."""
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(_path_str(p) for p in path)
        arr = np.asarray(leaf)
        if arr.dtype.kind == "V" or arr.dtype.name == "bfloat16":
            arr = arr.astype(np.float32)
        flat[key] = arr
    return flat


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return str(p.idx)
    return str(p)


def save(
    ckpt_dir: str | os.PathLike,
    step: int,
    tree,
    *,
    host_id: int = 0,
    num_hosts: int = 1,
    extra: dict | None = None,
    keep: int = 3,
) -> Path:
    """Write one checkpoint; returns its directory. Host 0 owns the manifest
    and LATEST pointer (call on every host; non-0 hosts only write shards)."""
    ckpt_dir = Path(ckpt_dir)
    name = f"step_{step:08d}"
    final = ckpt_dir / name
    tmp = ckpt_dir / (name + ".tmp")
    tmp.mkdir(parents=True, exist_ok=True)

    flat = _flatten(tree)
    shard_file = tmp / f"host{host_id:02d}.npz"
    np.savez(shard_file, **flat)
    crc = zlib.crc32(shard_file.read_bytes())

    if host_id == 0:
        manifest = {
            "step": step,
            "num_hosts": num_hosts,
            "leaves": sorted(flat),
            "shards": {f"host{host_id:02d}.npz": crc},
            "extra": extra or {},
        }
        (tmp / "manifest.json").write_text(json.dumps(manifest, indent=1))
        with open(tmp / "manifest.json", "rb") as f:
            os.fsync(f.fileno())
        if final.exists():
            shutil.rmtree(final)
        os.replace(tmp, final)
        _update_latest(ckpt_dir, name)
        _retain(ckpt_dir, keep)
    return final


def _update_latest(ckpt_dir: Path, name: str) -> None:
    tmp = ckpt_dir / "LATEST.tmp"
    tmp.write_text(name)
    os.replace(tmp, ckpt_dir / "LATEST")


def _retain(ckpt_dir: Path, keep: int) -> None:
    steps = sorted(d for d in ckpt_dir.glob("step_*") if d.is_dir()
                   and not d.name.endswith(".tmp"))
    for old in steps[:-keep]:
        shutil.rmtree(old, ignore_errors=True)


def latest_step(ckpt_dir: str | os.PathLike) -> int | None:
    ckpt_dir = Path(ckpt_dir)
    pointer = ckpt_dir / "LATEST"
    candidates = []
    if pointer.exists():
        candidates.append(ckpt_dir / pointer.read_text().strip())
    # fall back to directory scan (pointer may predate a crash)
    candidates += sorted(
        (d for d in ckpt_dir.glob("step_*") if d.is_dir()), reverse=True
    )
    for c in candidates:
        if (c / "manifest.json").exists():
            return int(json.loads((c / "manifest.json").read_text())["step"])
    return None


def _verify(ckpt: Path) -> bool:
    try:
        manifest = json.loads((ckpt / "manifest.json").read_text())
    except (OSError, json.JSONDecodeError):
        return False
    for shard, crc in manifest["shards"].items():
        f = ckpt / shard
        if not f.exists() or zlib.crc32(f.read_bytes()) != crc:
            return False
    return True


def restore(
    ckpt_dir: str | os.PathLike,
    tree,
    *,
    step: int | None = None,
    host_id: int = 0,
    shardings=None,
):
    """Restore ``tree``-structured arrays (+ manifest extra) from the newest
    valid checkpoint (or ``step``). Falls back to older checkpoints on
    corruption. ``shardings``: optional matching pytree of NamedShardings —
    pass the NEW mesh's shardings to restart elastically on different
    hardware."""
    ckpt_dir = Path(ckpt_dir)
    if step is not None:
        order = [ckpt_dir / f"step_{step:08d}"]
    else:
        order = sorted(
            (d for d in ckpt_dir.glob("step_*") if d.is_dir()), reverse=True
        )
    for ckpt in order:
        if not _verify(ckpt):
            continue
        manifest = json.loads((ckpt / "manifest.json").read_text())
        with np.load(ckpt / f"host{host_id:02d}.npz") as z:
            flat = {k: z[k] for k in z.files}
        leaves_paths = jax.tree_util.tree_flatten_with_path(tree)[0]
        treedef = jax.tree_util.tree_structure(tree)
        vals = []
        import jax.numpy as jnp

        for path, ref in leaves_paths:
            key = "/".join(_path_str(p) for p in path)
            arr = flat[key]
            if hasattr(ref, "dtype") and arr.dtype != ref.dtype:
                arr = jnp.asarray(arr).astype(ref.dtype)
            vals.append(arr)
        restored = jax.tree_util.tree_unflatten(treedef, vals)
        if shardings is not None:
            restored = jax.tree.map(
                lambda a, s: jax.device_put(a, s), restored, shardings
            )
        return restored, manifest["step"], manifest.get("extra", {})
    raise FileNotFoundError(f"no valid checkpoint under {ckpt_dir}")
