"""Train an LM for a few hundred steps with the full substrate:
deterministic data pipeline, grad accumulation, atomic checkpointing, and
a mid-run injected crash + restart (fault-tolerance demo).

Defaults are CPU-sized (~36M params, ~5 min). On real hardware scale up:

    PYTHONPATH=src python examples/train_lm.py \
        --d-model 768 --layers 12 --batch 32 --steps 300    # ~110M params
"""
from __future__ import annotations

import argparse
import shutil
import tempfile
from dataclasses import replace

from repro.configs import get_config
from repro.launch.mesh import make_host_mesh
from repro.models import Model, count_params
from repro.train.data import DataConfig
from repro.train.loop import FaultInjector, TrainConfig, Trainer


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--d-model", type=int, default=384)
    ap.add_argument("--layers", type=int, default=6)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--microbatches", type=int, default=2)
    args = ap.parse_args()

    # a qwen-family config (vocab dominates at small scale)
    cfg = replace(
        get_config("qwen1.5-0.5b"),
        num_layers=args.layers,
        d_model=args.d_model,
        num_heads=args.d_model // 64,
        num_kv_heads=args.d_model // 64,
        head_dim=64,
        d_ff=args.d_model * 3,
        vocab_size=32_000,
    )
    n = count_params(Model(cfg).describe())
    print(f"model: {n/1e6:.1f}M params, {args.steps} steps, "
          f"batch {args.batch} x seq {args.seq_len}, "
          f"{args.microbatches} microbatches")

    ckpt_dir = tempfile.mkdtemp(prefix="repro_train_")
    tcfg = TrainConfig(
        steps=args.steps,
        microbatches=args.microbatches,
        lr=1e-3,
        ckpt_every=max(10, args.steps // 8),
        ckpt_dir=ckpt_dir,
        log_every=max(1, args.steps // 20),
    )
    data = DataConfig(
        vocab_size=cfg.vocab_size, seq_len=args.seq_len,
        global_batch=args.batch,
    )
    trainer = Trainer(cfg, tcfg, make_host_mesh(), data)

    # crash mid-run, then restart from the checkpoint — same trajectory
    fault = FaultInjector(fail_at=(args.steps // 2,))
    state = trainer.resume_or_init()
    while True:
        try:
            state = trainer.run(state, fault)
            break
        except RuntimeError as e:
            print(f"!! {e} — restarting from newest checkpoint")
            state = trainer.resume_or_init()
            print(f"   resumed at step {state.step}")

    first, last = trainer.metrics[0], trainer.metrics[-1]
    print(f"\nloss {first['loss']:.3f} (step {first['step']}) -> "
          f"{last['loss']:.3f} (step {last['step']})")
    assert last["loss"] < first["loss"], "loss should decrease"
    shutil.rmtree(ckpt_dir, ignore_errors=True)
    print("ok")


if __name__ == "__main__":
    main()
