"""End-to-end serving driver: MORI vs the paper's baselines on one box.

Replays an agentic trace corpus against DP=2 real JAX engines (reduced
model) under every scheduler — mori / ta+o / ta / smg — with the GPU tier
deliberately undersized so placement policy matters, then prints the
comparison table (the laptop-scale analogue of paper Figs. 7-10).

    PYTHONPATH=src python examples/serve_agents.py [--programs 8]
"""
from __future__ import annotations

import argparse
import time

from repro.configs import get_config
from repro.core.scheduler import SchedulerConfig
from repro.models import Model, materialize
from repro.serving import Engine, MoriRouter
from repro.traces import TraceGenConfig, generate_corpus

SCHEDS = ["mori", "ta+o", "ta", "smg"]


def build_router(sched: str, cfg, params, replicas: int = 2) -> MoriRouter:
    engines = [
        Engine(
            cfg, params,
            page_tokens=16, n_device_pages=72, n_host_pages=160,
            max_slots=3, max_seq=384,
        )
        for _ in range(replicas)
    ]
    return MoriRouter(
        engines,
        scheduler=sched,
        # undersize the tiers so placement decisions are exercised
        gpu_capacity_bytes=engines[0].pool.page_bytes * 8,
        cpu_capacity_bytes=engines[0].pool.page_bytes * 20,
        config=SchedulerConfig(tick_interval_s=1.0),
    )


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--programs", type=int, default=8)
    ap.add_argument("--replicas", type=int, default=2)
    args = ap.parse_args()

    cfg = get_config("qwen1.5-0.5b").reduced()
    params = materialize(Model(cfg).describe(), seed=0)
    corpus = generate_corpus(
        args.programs, seed=1,
        cfg=TraceGenConfig(
            min_steps=4, mean_steps=7, max_steps=9,
            initial_context_mean=900, max_context=2400,
            long_median_s=45.0, busy_calls_mean=3.0, idle_calls_mean=3.0,
        ),
    )

    print(f"{args.programs} programs x {args.replicas} replicas, "
          f"schedulers: {', '.join(SCHEDS)}\n")
    header = (f"{'sched':<6} {'steps':>6} {'tokens':>7} {'hit%':>6} "
              f"{'offl':>6} {'reload':>7} {'gated':>6} {'ovlp':>5} "
              f"{'cancl':>6} {'wall_s':>7}")
    print(header)
    print("-" * len(header))
    for sched in SCHEDS:
        router = build_router(sched, cfg, params, args.replicas)
        t0 = time.time()
        m = router.replay(corpus, vocab_size=cfg.vocab_size, max_new_tokens=4)
        print(
            f"{sched:<6} {m.steps_completed:>6} {m.tokens_generated:>7} "
            f"{m.cache_hit_rate:>6.1%} {m.offloaded_pages:>6} "
            f"{m.reloaded_pages:>7} {m.gated_events:>6} "
            f"{m.overlap_decode_steps:>5} {m.cancelled_offloads:>6} "
            f"{time.time() - t0:>7.1f}"
        )
    print("\nhigher hit% / fewer gated events = better placement; the paper's"
          "\nthroughput/TTFT deltas at scale are reproduced in "
          "benchmarks/single_replica.py")


if __name__ == "__main__":
    main()
