"""Quickstart: the MORI scheduler end-to-end in ~60 seconds on CPU.

Serves a reduced dense model with the real JAX engine behind the MORI
router, replays a small agentic trace corpus, and prints the placement /
cache metrics the paper's evaluation is built on.

    python examples/quickstart.py
"""
from __future__ import annotations

import sys
from pathlib import Path

try:
    import repro  # noqa: F401  (installed, or PYTHONPATH=src)
except ImportError:
    sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from repro.configs import get_config
from repro.dist import make_replica_set
from repro.models import Model, materialize
from repro.serving import Engine, MoriRouter
from repro.traces import TraceGenConfig, generate_corpus


def main() -> None:
    # 1. a reduced qwen1.5-family config (CPU-sized; same code path as 0.5B)
    cfg = get_config("qwen1.5-0.5b").reduced()
    params = materialize(Model(cfg).describe(), seed=0)

    # 2. a one-replica placement on the 1x1 CPU host mesh — the same
    #    repro.dist decode rules the 256-chip production mesh uses
    replica_set = make_replica_set(1, num_kv_heads=cfg.num_kv_heads)
    placement = replica_set.placement(0)
    print(f"host mesh {dict(placement.mesh.shape)}, "
          f"logits spec {placement.spec(('batch', 'vocab_act'))}")

    # 3. one real engine: paged KV pool (device+host tiers), radix prefix
    #    cache with typed eviction, continuous-batching decode
    engine = Engine(
        cfg, params,
        page_tokens=16, n_device_pages=96, n_host_pages=192,
        max_slots=4, max_seq=256,
        placement=placement,
    )

    # 4. the MORI router: windowed idleness ranking, three-tier placement,
    #    sticky rebalancing, admission control (paper §4)
    router = MoriRouter([engine], scheduler="mori")

    # 5. a Claude-Code-like trace corpus (busy/idle two-phase structure, §3)
    corpus = generate_corpus(
        6, seed=0,
        cfg=TraceGenConfig(
            min_steps=3, mean_steps=5, max_steps=6,
            initial_context_mean=600, max_context=2000,
        ),
    )

    print(f"replaying {len(corpus)} agent programs on 1 engine...")
    m = router.replay(corpus, vocab_size=cfg.vocab_size, max_new_tokens=4)

    print(f"  completed steps     : {m.steps_completed}")
    print(f"  output tokens       : {m.tokens_generated}")
    print(f"  cache hit rate      : {m.cache_hit_rate:.1%}")
    print(f"  pages offloaded     : {m.offloaded_pages}")
    print(f"  pages reloaded      : {m.reloaded_pages}")
    print(f"  gated events        : {m.gated_events}")
    assert m.steps_completed > 0
    print("ok — see examples/serve_agents.py for the full driver")


if __name__ == "__main__":
    main()
