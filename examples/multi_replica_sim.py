"""Paper-scale what-if: the DP=3 multi-replica experiment (Fig. 10) in the
calibrated discrete-event simulator, plus a replica-failure scenario the
paper doesn't show — MORI's Waiting-queue semantics double as the recovery
path when an engine dies.

    PYTHONPATH=src python examples/multi_replica_sim.py
"""
from __future__ import annotations

from repro.dist import make_replica_set
from repro.sim import CONFIGS, FaultPlan, Simulation
from repro.traces import generate_corpus

HW = "h200-qwen3-30b-a3b"


def _placement():
    # DP=3 fleet layout: three replicas sharing one rules object, exactly
    # how repro.launch.serve places the real engines. On the host mesh the
    # rules carry layout provenance only (every spec replicates), so the
    # default num_kv_heads is irrelevant here.
    return make_replica_set(3)


def run(sched: str, *, conc: int, faults: list[FaultPlan] | None = None):
    sim = Simulation(
        sched,
        CONFIGS[HW],
        generate_corpus(64, seed=0),
        placement=_placement(),
        concurrency_per_replica=conc,
        cpu_ratio=2.0,
        duration_s=600.0,
        warmup_s=120.0,
        seed=0,
        faults=faults,
    )
    return sim.run()


def main() -> None:
    print(f"=== Fig.10 slice: {HW}, DP=3, 2x CPU, 600s sim ===")
    header = (f"{'sched':<6} {'conc':>5} {'tok/s':>8} {'ttft_s':>7} "
              f"{'util':>6} {'churn':>7}")
    print(header)
    print("-" * len(header))
    for conc in (20, 80):
        for sched in ("mori", "ta+o", "ta", "smg"):
            r = run(sched, conc=conc)
            print(f"{sched:<6} {conc:>5} {r.output_tok_per_s:>8.0f} "
                  f"{r.ttft_avg_s:>7.1f} {r.gpu_util:>6.1%} "
                  f"{r.switches_per_program:>7.3f}")

    print("\n=== replica 1 fails at t=240s, recovers at t=420s "
          "(beyond-paper scenario) ===")
    for sched in ("mori", "ta+o"):
        r = run(sched, conc=50,
                faults=[FaultPlan(replica=1, fail_at=240.0, recover_at=420.0)])
        print(f"{sched:<6} tok/s {r.output_tok_per_s:>7.0f}  "
              f"ttft {r.ttft_avg_s:>6.1f}s  finished {r.programs_finished}")
    print("\nMORI re-admits the dead replica's programs through the Waiting "
          "queue\n(recompute path) and re-balances via BFD — no stuck "
          "programs.")


if __name__ == "__main__":
    main()
